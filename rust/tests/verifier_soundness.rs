//! Verifier ⇔ runtime soundness sweep (in-repo `run_prop` driver).
//!
//! The static verifier's contract (docs/ANALYSIS.md): relative to the
//! entry context of a freshly built engine,
//!
//! * **accepted ⇒ runs clean** — `Engine::execute` returns `Ok` on
//!   both execution legs (fused replay and the reference interpreter),
//!   and the report's static cycle count equals the executed one;
//! * **rejected ⇒ faults** — `Engine::execute` returns a typed
//!   `EngineError` (never a panic) on both legs.
//!
//! The generator draws instructions with deliberately out-of-range
//! fields (registers ≥ 32, SELBLK columns past the array, SETP values
//! the Op-Params module rejects, spill pairs past the register column,
//! aliasing MAC windows, FOLD levels that saturate the group size) and
//! sometimes leaves the stream unsealed, so every diagnostic class the
//! verifier can emit shows up in the sweep.

use imagine::analysis::{verify, DiagKind, VerifyCtx};
use imagine::engine::{Engine, EngineConfig, SEL_ALL};
use imagine::isa::{Instr, Opcode, Program};
use imagine::util::rng::{run_prop, XorShift};

/// Mostly-valid register field, occasionally architectural-max or out
/// of range (the encoder would mask it; the verifier must not).
fn gen_reg(rng: &mut XorShift) -> u8 {
    match rng.below(8) {
        0..=4 => rng.range(0, 7) as u8,
        5 | 6 => rng.range(0, 31) as u8,
        _ => rng.range(32, 63) as u8,
    }
}

fn gen_instr(rng: &mut XorShift) -> Instr {
    let op = *rng.pick(&Opcode::ALL);
    match op {
        Opcode::Nop | Opcode::Sync | Opcode::Halt | Opcode::Rshift => {
            Instr::new(op, 0, 0, 0, 0)
        }
        Opcode::Selblk => Instr::selblk(*rng.pick(&[0, 1, 2, 3, 4, 5, 64, 999, SEL_ALL])),
        // param index 3 is unknown; values cover both sides of every
        // Op-Params bound (precision 2..=16, acc_width <=64, radix 2|4)
        Opcode::Setp => Instr::setp(
            rng.range(0, 3) as u8,
            *rng.pick(&[0, 1, 2, 4, 8, 12, 16, 17, 32, 48, 64, 65]),
        ),
        Opcode::Ldi | Opcode::Write => {
            Instr::new(op, gen_reg(rng), 0, 0, rng.below(1024) as u16)
        }
        Opcode::Read => Instr::read(gen_reg(rng)),
        Opcode::Mov => Instr::mov(gen_reg(rng), gen_reg(rng)),
        Opcode::Add | Opcode::Sub => Instr::new(op, gen_reg(rng), gen_reg(rng), gen_reg(rng), 0),
        // imm > 0 is a spill-pair pointer: 48/49 straddle the p=8
        // register-column boundary (pair 47 ends exactly at bit 1024)
        Opcode::Mult | Opcode::Mac => Instr::new(
            op,
            gen_reg(rng),
            gen_reg(rng),
            gen_reg(rng),
            *rng.pick(&[0, 0, 0, 0, 1, 2, 8, 47, 48, 49, 50, 300]),
        ),
        Opcode::Accum => Instr::accum(gen_reg(rng), rng.below(8) as u16),
        // levels >= 59 saturate the fold group (lint, never a fault)
        Opcode::Fold => {
            Instr::fold(gen_reg(rng), *rng.pick(&[0, 1, 2, 3, 4, 5, 6, 10, 59, 60, 63, 1023]))
        }
    }
}

fn dump(prog: &Program) -> String {
    prog.instrs
        .iter()
        .enumerate()
        .map(|(i, x)| format!("  @{i}: {x}"))
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn verifier_matches_runtime_on_random_programs() {
    let cfg = EngineConfig::small();
    let ctx = VerifyCtx::for_engine(&cfg);
    run_prop("verifier soundness", 250, |rng| {
        let mut prog: Program = (0..rng.range(1, 10)).map(|_| gen_instr(rng)).collect();
        if rng.below(8) != 0 {
            prog.seal();
        }
        let report = verify(&prog, &ctx);
        for fuse in [false, true] {
            let mut e = Engine::with_threads(cfg, 1);
            e.set_fuse(fuse);
            match e.execute(&prog) {
                Ok(stats) => {
                    assert!(
                        report.accepts(),
                        "verifier rejected but the engine (fuse={fuse}) ran clean\n\
                         program:\n{}\nreport:\n{report}",
                        dump(&prog)
                    );
                    assert_eq!(
                        stats.cycles,
                        report.cost.cycles,
                        "static cycle count diverges (fuse={fuse})\nprogram:\n{}",
                        dump(&prog)
                    );
                }
                Err(err) => {
                    assert!(
                        !report.accepts(),
                        "verifier accepted but the engine (fuse={fuse}) faulted: {err}\n\
                         program:\n{}\nreport:\n{report}",
                        dump(&prog)
                    );
                }
            }
        }
    });
}

/// One hand-built program per error class: the verifier must reject
/// with exactly that diagnostic kind, and the engine must fault on
/// both legs from the matching entry state.
#[test]
fn every_error_class_is_rejected_and_faults() {
    let cfg = EngineConfig::small();
    let ctx = VerifyCtx::for_engine(&cfg);
    let lanes = cfg.pe_rows();

    let mut underflow: Program =
        std::iter::once(Instr::read(4)).chain((0..=lanes).map(|_| Instr::rshift())).collect();
    underflow.seal();

    let cases: Vec<(&str, Program, DiagKind)> = vec![
        (
            "post_halt",
            [Instr::halt(), Instr::nop(), Instr::halt()].into_iter().collect(),
            DiagKind::PostHalt,
        ),
        (
            "bad_setp_value",
            [Instr::setp(0, 1), Instr::halt()].into_iter().collect(),
            DiagKind::BadSetp,
        ),
        (
            "bad_setp_index",
            [Instr::setp(3, 8), Instr::halt()].into_iter().collect(),
            DiagKind::BadSetp,
        ),
        (
            "bad_column",
            [Instr::selblk(999), Instr::halt()].into_iter().collect(),
            DiagKind::BadColumn,
        ),
        (
            "bad_reg",
            [Instr::mov(40, 0), Instr::halt()].into_iter().collect(),
            DiagKind::BadReg,
        ),
        (
            "window_overflow",
            [Instr::setp(1, 64), Instr::mov(31, 0), Instr::halt()].into_iter().collect(),
            DiagKind::WindowOverflow,
        ),
        ("fifo_underflow", underflow, DiagKind::FifoUnderflow),
        (
            "spill_overflow",
            [Instr::new(Opcode::Mac, 4, 1, 2, 49), Instr::halt()].into_iter().collect(),
            DiagKind::SpillOverflow,
        ),
        (
            "operand_alias",
            [Instr::mult(4, 4, 2), Instr::halt()].into_iter().collect(),
            DiagKind::OperandAlias,
        ),
        ("not_sealed", [Instr::nop()].into_iter().collect(), DiagKind::NotSealed),
    ];

    for (name, prog, kind) in cases {
        let report = verify(&prog, &ctx);
        assert!(!report.accepts(), "{name}: expected rejection, got:\n{report}");
        assert!(
            report.errors.iter().any(|d| d.kind == kind),
            "{name}: expected {kind:?}, got:\n{report}"
        );
        for fuse in [false, true] {
            let mut e = Engine::with_threads(cfg, 1);
            e.set_fuse(fuse);
            assert!(
                e.execute(&prog).is_err(),
                "{name}: verifier rejected but the engine (fuse={fuse}) ran clean"
            );
        }
    }
}

/// The flip side, pinned on a known-good stream: accepted, zero lints,
/// identical cycles on both legs, and the result readback matches.
#[test]
fn accepted_program_runs_clean_on_both_legs() {
    let cfg = EngineConfig::small();
    let ctx = VerifyCtx::for_engine(&cfg);
    let prog: Program = [
        Instr::setp(0, 8),
        Instr::ldi(1, 3),
        Instr::ldi(2, 5),
        Instr::mult(4, 1, 2),
        // ncols-1 hops gather every column's product into column 0
        Instr::accum(4, 3),
        Instr::read(4),
        Instr::rshift(),
        Instr::halt(),
    ]
    .into_iter()
    .collect();
    let report = verify(&prog, &ctx);
    assert!(report.accepts(), "{report}");
    for fuse in [false, true] {
        let mut e = Engine::with_threads(cfg, 1);
        e.set_fuse(fuse);
        let stats = e.execute(&prog).unwrap();
        assert_eq!(stats.cycles, report.cost.cycles, "fuse={fuse}");
        // 3 * 5, accumulated across the 4 columns by the systolic hop
        assert_eq!(e.drain_fifo()[0], 3 * 5 * cfg.block_cols() as i64, "fuse={fuse}");
    }
}
