//! Whole-stack fixture programs written in assembly text, assembled and
//! executed on the engine — the workflow a user debugging the overlay
//! would follow.

use imagine::engine::{Engine, EngineConfig};
use imagine::isa::{assemble, disassemble};

#[test]
fn broadcast_multiply_accumulate_program() {
    // load constants, multiply, accumulate east->west, read out
    let src = "\
        setp p0, 8          ; precision 8\n\
        setp p1, 24         ; accumulator width\n\
        ldi r1, 7           ; w = 7 everywhere\n\
        ldi r2, 0x3F        ; x = 63 everywhere\n\
        mult r4, r1, r2     ; acc = 441 in every column\n\
        accum r4, 3         ; 4 columns -> west col holds 4*441\n\
        read r4\n\
        rshift\n\
        rshift\n\
        halt\n";
    let prog = assemble(src).unwrap();
    let mut e = Engine::new(EngineConfig::small());
    let stats = e.execute(&prog).unwrap();
    assert_eq!(e.drain_fifo(), vec![4 * 441, 4 * 441]);
    // multicycle mix: mult + accum
    assert_eq!(prog.driver_mix().1, 2);
    assert!(stats.cycles > 0);
}

#[test]
fn selective_column_program() {
    let src = "\
        setp p0, 8\n\
        selblk 2\n\
        ldi r1, 5\n\
        selblk 0x3ff\n\
        halt\n";
    let prog = assemble(src).unwrap();
    let mut e = Engine::new(EngineConfig::small());
    e.execute(&prog).unwrap();
    assert!(e.read_reg_lanes(2, 1, 8).unwrap().iter().all(|&v| v == 5));
    assert!(e.read_reg_lanes(0, 1, 8).unwrap().iter().all(|&v| v == 0));
}

#[test]
fn add_sub_chain_program() {
    let src = "\
        setp p0, 8\n\
        setp p1, 16\n\
        ldi r1, 100\n\
        ldi r2, 42\n\
        add r4, r1, r2      ; 142\n\
        sub r5, r1, r2      ; 58\n\
        add r6, r4, r5      ; 200\n\
        halt\n";
    let prog = assemble(src).unwrap();
    let mut e = Engine::new(EngineConfig::small());
    e.execute(&prog).unwrap();
    assert!(e.read_reg_lanes(0, 6, 16).unwrap().iter().all(|&v| v == 200));
    assert!(e.read_reg_lanes(3, 5, 16).unwrap().iter().all(|&v| v == 58));
}

#[test]
fn booth_program_matches_radix2_program() {
    let base = "\
        setp p0, 8\n\
        setp p1, 20\n\
        ldi r1, 0x3B5       ; -75 (sign-extended imm10)\n\
        ldi r2, 93\n\
        mult r4, r1, r2\n\
        halt\n";
    let mut e2 = Engine::new(EngineConfig::small());
    e2.execute(&assemble(base).unwrap()).unwrap();
    let booth = format!("setp p2, 4\n{base}");
    let mut e4 = Engine::new(EngineConfig::small());
    e4.execute(&assemble(&booth).unwrap()).unwrap();
    let want = -75i64 * 93;
    assert!(e2.read_reg_lanes(0, 4, 20).unwrap().iter().all(|&v| v == want));
    assert_eq!(
        e2.read_reg_lanes(0, 4, 20).unwrap(),
        e4.read_reg_lanes(0, 4, 20).unwrap()
    );
}

#[test]
fn disassembly_roundtrips_through_the_engine() {
    let src = "setp p0, 8\nldi r1, 9\nmov r3, r1\nhalt\n";
    let p1 = assemble(src).unwrap();
    let p2 = assemble(&disassemble(&p1)).unwrap();
    assert_eq!(p1, p2);
    let mut e = Engine::new(EngineConfig::small());
    e.execute(&p2).unwrap();
    assert!(e.read_reg_lanes(1, 3, 8).unwrap().iter().all(|&v| v == 9));
}
