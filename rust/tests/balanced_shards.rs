//! Occupancy-weighted shard balancing properties (docs/PERF.md
//! §Occupancy-weighted shard balancing).
//!
//! The weighted planner must be a pure re-partitioning: same K, same
//! coverage, bit-identical `y` — only the boundaries move. On
//! column-structured skew (the sparsity shape bit-serial occupancy
//! skipping can actually exploit: a plane word skips only when ALL
//! lanes packed into it are zero) the weighted boundaries must reduce
//! the measured per-member work spread vs the geometric split, and the
//! host-side estimator's per-member shares must track the measured
//! shares. With skipping disabled the weighted planner must fall back
//! to the geometric split exactly — work *is* the row count then.
//!
//! Skip mode is forced per test (`force_skip`), so every assertion
//! here is deterministic across the `IMAGINE_SKIP` / `IMAGINE_TRACE`
//! CI legs; trace replay drives the same column ALU ops, so measured
//! work is mode-independent.

use imagine::engine::EngineConfig;
use imagine::gemv::{
    col_work_estimates, imbalance_milli, plan_col_shards_k, plan_col_shards_k_weighted,
    plan_shards_k, plan_shards_k_weighted, row_work_estimates, ColShardedScheduler, GemvScheduler,
    ShardedScheduler,
};
use imagine::pim::alu::force_skip;
use imagine::util::rng::XorShift;

fn host_gemv(w: &[i64], x: &[i64], m: usize, n: usize) -> Vec<i64> {
    (0..m)
        .map(|r| (0..n).map(|j| w[r * n + j] * x[j]).sum())
        .collect()
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Pattern {
    DenseTop,
    DenseBottom,
    Banded,
    Uniform,
}

const PATTERNS: [Pattern; 4] =
    [Pattern::DenseTop, Pattern::DenseBottom, Pattern::Banded, Pattern::Uniform];

/// Column-structured row skew: dense rows carry full-range values in
/// every column; sparse rows are nonzero only in the first n/10
/// columns. Dense rows are contiguous, so the 64-lane plane words of a
/// row shard are either dominated by dense rows or all-sparse — the
/// shape where occupancy skipping changes per-shard work.
fn skewed_matrix(pat: Pattern, m: usize, n: usize, p: usize, rng: &mut XorShift) -> Vec<i64> {
    let half = 1i64 << (p - 1);
    let dense = |r: usize| match pat {
        Pattern::DenseTop => r < m / 4,
        Pattern::DenseBottom => r >= 3 * m / 4,
        // asymmetric on purpose: a band centered on m/2 would make the
        // balanced k=2 boundary coincide with the geometric one
        Pattern::Banded => (m / 8..3 * m / 8).contains(&r),
        Pattern::Uniform => true,
    };
    let mut w = vec![0i64; m * n];
    for r in 0..m {
        let cols = if dense(r) { n } else { n / 10 };
        let vals = rng.vec_i64(cols, -half, half - 1);
        w[r * n..r * n + cols].copy_from_slice(&vals);
    }
    w
}

/// Run `sp` twice (cold then resident) and return the hot batch's
/// measured per-shard work — the compute-dominated measurement where
/// occupancy, not staging, sets the spread.
fn hot_shard_work(
    sched: &mut ShardedScheduler,
    sp: &imagine::gemv::ShardPlan,
    token: u64,
    w: &[i64],
    x: &[i64],
    expect: &[i64],
) -> Vec<u64> {
    let xrefs: Vec<&[i64]> = vec![x];
    for round in 0..2 {
        let out = sched.run_plan(sp, token, w, &xrefs);
        let (y, _) = out.into_iter().next().unwrap().unwrap();
        assert_eq!(y, expect, "round {round} token {token}");
    }
    sched.last_shard_work().to_vec()
}

#[test]
fn weighted_row_shards_bit_identical_and_balanced() {
    let _skip = force_skip(true);
    let config = EngineConfig::small();
    let (m, n) = (192, 64);
    let mut rng = XorShift::new(81);
    let mut token = 9000u64;
    for pat in PATTERNS {
        for p in [4usize, 8, 16] {
            let half = 1i64 << (p - 1);
            let w = skewed_matrix(pat, m, n, p, &mut rng);
            let x = rng.vec_i64(n, -half, half - 1);
            let expect = host_gemv(&w, &x, m, n);
            let est = row_work_estimates(&w, m, n);
            for k in [2usize, 4, 8] {
                let geo = plan_shards_k(m, n, p, 2, k);
                let wp = plan_shards_k_weighted(m, n, p, 2, k, Some(&est));
                assert_eq!(wp.k(), k, "weighted planning must not change K");
                assert_eq!(
                    wp.shards.iter().map(|s| s.rows).sum::<usize>(),
                    m,
                    "weighted shards must cover every row"
                );
                if pat != Pattern::Uniform {
                    assert_ne!(
                        geo.shards, wp.shards,
                        "{pat:?} p={p} k={k}: skew must move the boundaries"
                    );
                    // planner-level: estimated work spread shrinks
                    let geo_est: Vec<u64> = geo
                        .shards
                        .iter()
                        .map(|s| est[s.row0..s.row0 + s.rows].iter().sum())
                        .collect();
                    assert!(
                        imbalance_milli(&wp.estimated_work) <= imbalance_milli(&geo_est),
                        "{pat:?} p={p} k={k}: weighted estimated spread must not exceed geometric"
                    );
                }
                // fresh pool + distinct tokens per plan: a member keys
                // staged weights by (token, shape), and these two plans
                // intentionally disagree about shapes
                let mut sched = ShardedScheduler::with_threads(config, 2, 1);
                token += 2;
                let gw = hot_shard_work(&mut sched, &geo, token, &w, &x, &expect);
                let ww = hot_shard_work(&mut sched, &wp, token + 1, &w, &x, &expect);
                let (g_imb, w_imb) = (imbalance_milli(&gw), imbalance_milli(&ww));
                if matches!(pat, Pattern::DenseTop | Pattern::DenseBottom) {
                    assert!(
                        w_imb <= g_imb * 105 / 100 + 60,
                        "{pat:?} p={p} k={k}: weighted measured imbalance {w_imb} \
                         worse than geometric {g_imb}"
                    );
                }
                // estimator accuracy: per-member estimated share tracks
                // the measured share (banded boundaries can split a
                // plane word mid-band, where additive row estimates and
                // union-semantics measurement legitimately diverge)
                if pat != Pattern::Banded {
                    let est_total: u64 = wp.estimated_work.iter().sum();
                    let meas_total: u64 = ww.iter().sum();
                    assert!(meas_total > 0, "{pat:?} p={p} k={k}: no measured work");
                    for (i, (e, mw)) in wp.estimated_work.iter().zip(&ww).enumerate() {
                        let es = *e as f64 / est_total as f64;
                        let ms = *mw as f64 / meas_total as f64;
                        assert!(
                            (es - ms).abs() <= 0.35,
                            "{pat:?} p={p} k={k} shard {i}: estimated share {es:.3} \
                             vs measured {ms:.3}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn weighted_row_shards_match_native_engine() {
    let _skip = force_skip(true);
    let config = EngineConfig::small();
    let (m, n, p) = (192, 64, 8);
    let mut rng = XorShift::new(83);
    let w = skewed_matrix(Pattern::DenseTop, m, n, p, &mut rng);
    let x = rng.vec_i64(n, -128, 127);
    let mut native = GemvScheduler::new(config);
    let want = native.gemv(&w, &x, m, n, p, 2).unwrap().0;
    let est = row_work_estimates(&w, m, n);
    let wp = plan_shards_k_weighted(m, n, p, 2, 4, Some(&est));
    let mut sched = ShardedScheduler::with_threads(config, 2, 1);
    let xrefs: Vec<&[i64]> = vec![&x];
    let out = sched.run_plan(&wp, 7100, &w, &xrefs);
    assert_eq!(out.into_iter().next().unwrap().unwrap().0, want);
}

#[test]
fn weighted_col_slices_bit_identical_and_balanced() {
    let _skip = force_skip(true);
    let config = EngineConfig::single_tile();
    let (m, n, p) = (16, 96, 8);
    let half = 1i64 << (p - 1);
    let mut rng = XorShift::new(85);
    // dense-left column skew: the first quarter of the columns carries
    // full-range values, the rest are zero — for the column tier the
    // per-column estimate is exact (a slice owns whole columns, so no
    // lane-packing union effects)
    let mut w = vec![0i64; m * n];
    for r in 0..m {
        let vals = rng.vec_i64(n / 4, -half, half - 1);
        w[r * n..r * n + n / 4].copy_from_slice(&vals);
    }
    let x = rng.vec_i64(n, -half, half - 1);
    let expect = host_gemv(&w, &x, m, n);
    let est = col_work_estimates(&w, m, n);
    let xrefs: Vec<&[i64]> = vec![&x];
    let mut token = 9500u64;
    for k in [2usize, 4, 8] {
        let geo = plan_col_shards_k(m, n, p, 2, k);
        let wp = plan_col_shards_k_weighted(m, n, p, 2, k, Some(&est));
        assert_eq!(wp.k(), k);
        assert_eq!(wp.slices.iter().map(|s| s.cols).sum::<usize>(), n);
        assert_ne!(geo.slices, wp.slices, "k={k}: column skew must move the boundaries");
        let mut sched = ColShardedScheduler::with_threads(config, 2, 1);
        token += 2;
        let mut run = |cp: &imagine::gemv::ColShardPlan, t: u64| -> Vec<u64> {
            for round in 0..2 {
                let out = sched.run_plan(cp, t, &w, &xrefs);
                let (y, _) = out.into_iter().next().unwrap().unwrap();
                assert_eq!(y, expect, "k={k} round {round}");
            }
            sched.last_slice_work().to_vec()
        };
        let gw = run(&geo, token);
        let ww = run(&wp, token + 1);
        let (g_imb, w_imb) = (imbalance_milli(&gw), imbalance_milli(&ww));
        assert!(
            w_imb <= g_imb * 105 / 100 + 60,
            "k={k}: weighted measured imbalance {w_imb} worse than geometric {g_imb}"
        );
    }
}

#[test]
fn skip_disabled_falls_back_to_geometric_plans() {
    let _skip = force_skip(false);
    let (m, n, p) = (192, 64, 8);
    let mut rng = XorShift::new(87);
    let w = skewed_matrix(Pattern::DenseTop, m, n, p, &mut rng);
    let row_est = row_work_estimates(&w, m, n);
    let col_est = col_work_estimates(&w, m, n);
    for k in [2usize, 4, 8] {
        assert_eq!(
            plan_shards_k_weighted(m, n, p, 2, k, Some(&row_est)),
            plan_shards_k(m, n, p, 2, k),
            "k={k}: with skipping off, work is the row count — geometric is already balanced"
        );
        assert_eq!(
            plan_col_shards_k_weighted(m, n, p, 2, k, Some(&col_est)),
            plan_col_shards_k(m, n, p, 2, k),
            "k={k}: column tier must fall back too"
        );
    }
    // and the geometric plan still serves bit-identically with skip off
    let x = rng.vec_i64(n, -128, 127);
    let xrefs: Vec<&[i64]> = vec![&x];
    let mut sched = ShardedScheduler::with_threads(EngineConfig::small(), 2, 1);
    let sp = plan_shards_k_weighted(m, n, p, 2, 4, Some(&row_est));
    let out = sched.run_plan(&sp, 9900, &w, &xrefs);
    assert_eq!(out.into_iter().next().unwrap().unwrap().0, host_gemv(&w, &x, m, n));
}
