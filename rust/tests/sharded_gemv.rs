//! Sharded multi-engine GEMV semantics: row-sharding across an engine
//! pool must be observationally identical in `y` to the single-engine
//! path (property-tested across K and random shapes), per-shard
//! `ExecStats` must sum to the per-vector totals, per-shard residency
//! must cut the re-staging work for resident batches, and the
//! coordinator must transparently promote oversized models.

use imagine::coordinator::{Coordinator, CoordinatorConfig, ModelRegistry, Request};
use imagine::engine::EngineConfig;
use imagine::gemv::{plan, plan_shards, plan_shards_k, GemvScheduler, ShardedScheduler};
use imagine::sim::ExecStats;
use imagine::util::rng::{run_prop, XorShift};

fn host_gemv(w: &[i64], x: &[i64], m: usize, n: usize) -> Vec<i64> {
    (0..m)
        .map(|r| (0..n).map(|j| w[r * n + j] * x[j]).sum())
        .collect()
}

#[test]
fn prop_sharded_bit_identical_to_single_engine() {
    let config = EngineConfig::small();
    let mut sharded = ShardedScheduler::with_threads(config, 2, 1);
    let mut token = 0u64;
    run_prop("sharded y == single-engine y (K = 2, 3, 4)", 8, |rng| {
        let m = rng.range(4, 160);
        let n = rng.range(8, 120);
        let p = *rng.pick(&[4usize, 8]);
        let radix = if rng.bool() { 2 } else { 4 };
        let half = 1i64 << (p - 1);
        let w = rng.vec_i64(m * n, -half, half - 1);
        let xs: Vec<Vec<i64>> = (0..3).map(|_| rng.vec_i64(n, -half, half - 1)).collect();
        let xrefs: Vec<&[i64]> = xs.iter().map(|x| x.as_slice()).collect();

        let mut single = GemvScheduler::new(config);
        let solo: Vec<Vec<i64>> = xs
            .iter()
            .map(|x| single.gemv(&w, x, m, n, p, radix).unwrap().0)
            .collect();

        for k in [2usize, 3, 4] {
            // fresh token per (case, k): distinct matrices must never
            // share a residency identity
            token += 1;
            let sp = plan_shards_k(m, n, p, radix, k);
            let out = sharded.run_plan(&sp, token, &w, &xrefs);
            assert_eq!(out.len(), xs.len());
            for (j, r) in out.into_iter().enumerate() {
                let (y, stats) = r.unwrap_or_else(|e| panic!("k={k} vector {j}: {e}"));
                assert_eq!(y, solo[j], "k={k} vector {j} m={m} n={n} p={p} radix={radix}");
                assert!(stats.cycles > 0);
            }
        }
    });
}

#[test]
fn per_shard_stats_sum_to_vector_totals() {
    let config = EngineConfig::small();
    let (m, n, p) = (96, 64, 8);
    let mut rng = XorShift::new(61);
    let w = rng.vec_i64(m * n, -100, 100);
    let xs: Vec<Vec<i64>> = (0..4).map(|_| rng.vec_i64(n, -100, 100)).collect();
    let xrefs: Vec<&[i64]> = xs.iter().map(|x| x.as_slice()).collect();
    let mut sharded = ShardedScheduler::with_threads(config, 2, 1);
    let sp = plan_shards_k(m, n, p, 2, 3);
    let out = sharded.run_plan(&sp, 5, &w, &xrefs);

    let mut from_vectors = ExecStats::default();
    for r in out {
        from_vectors.merge(&r.unwrap().1);
    }
    let mut from_shards = ExecStats::default();
    assert_eq!(sharded.last_shard_stats().len(), 3);
    for s in sharded.last_shard_stats() {
        assert!(s.cycles > 0, "idle shard");
        assert!(s.plane_word_ops > 0, "shard did no plane work");
        from_shards.merge(s);
    }
    assert_eq!(from_vectors, from_shards, "shard totals != vector totals");
}

#[test]
fn per_shard_residency_cuts_restaging_work() {
    // multi-pass on one small() engine (768 > 384 lanes), 2 shards
    let config = EngineConfig::small();
    let (m, n, p) = (768, 64, 8);
    assert!(!plan(&config, m, n, p, 2).is_single_pass());
    let sp = plan_shards(&config, m, n, p, 2).expect("shardable");
    assert!(sp.resident_on(&config));

    let mut rng = XorShift::new(67);
    let w = rng.vec_i64(m * n, -16, 15);
    let xs: Vec<Vec<i64>> = (0..4).map(|_| rng.vec_i64(n, -64, 63)).collect();
    let xrefs: Vec<&[i64]> = xs.iter().map(|x| x.as_slice()).collect();
    let mut sharded = ShardedScheduler::with_threads(config, 2, 1);

    let work = |out: Vec<imagine::gemv::GemvOutcome>| -> u64 {
        out.into_iter().map(|r| r.unwrap().1.plane_word_ops).sum()
    };
    // batch 1: every shard stages its row-slice once (cold)
    let cold = work(sharded.run_plan(&sp, 9, &w, &xrefs));
    // batch 2, same token: shards are resident — only vectors move
    let hot = work(sharded.run_plan(&sp, 9, &w, &xrefs));
    assert!(
        hot < cold,
        "resident batch must re-stage less: hot {hot} !< cold {cold}"
    );

    // single-engine multi-pass baseline re-stages every vector
    let mut single = GemvScheduler::new(config);
    let single_work: u64 = xs
        .iter()
        .map(|x| single.gemv(&w, x, m, n, p, 2).unwrap().1.plane_word_ops)
        .sum();
    assert!(
        hot < single_work,
        "sharded resident batch must beat multi-pass re-staging: {hot} !< {single_work}"
    );
}

#[test]
fn coordinator_promotes_oversized_gemv_to_sharded_pool() {
    let (m, n) = (768, 32);
    let engine = EngineConfig::small();
    assert!(
        plan_shards(&engine, m, n, 8, 2).is_some(),
        "shape must promote for this test to bite"
    );
    let mut rng = XorShift::new(71);
    let w = rng.vec_i64(m * n, -16, 15);
    let reg = ModelRegistry::default();
    reg.register_gemv("wide", w.clone(), m, n).unwrap();
    reg.register_gemv("small", rng.vec_i64(16 * 32, -16, 15), 16, 32).unwrap();
    let coord = Coordinator::start(CoordinatorConfig { workers: 2, ..Default::default() }, reg);
    let mut rxs = Vec::new();
    let mut want = Vec::new();
    for i in 0..12 {
        let x = rng.vec_i64(32, -64, 63);
        let model = if i % 3 == 0 { "small" } else { "wide" };
        if model == "wide" {
            want.push(Some(host_gemv(&w, &x, m, n)));
        } else {
            want.push(None);
        }
        rxs.push(coord.submit(Request::new(model, x)).unwrap());
    }
    for (rx, want) in rxs.into_iter().zip(want) {
        let resp = rx.recv().unwrap().unwrap();
        if let Some(y) = want {
            assert_eq!(resp.y, y);
        }
    }
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 12);
    assert_eq!(snap.failed, 0);
}
