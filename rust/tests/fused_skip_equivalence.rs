//! Equivalence sweep for the two hot-path optimizations (ISSUE 3):
//! fused compiled-kernel dispatch (`IMAGINE_FUSE`) and occupancy-aware
//! plane/word skipping (`IMAGINE_SKIP`) must be *observably invisible*
//! — `y`, `ExecStats.cycles` and `plane_word_ops` bit-identical to the
//! per-instruction, full-width-walk reference — across sparsity
//! (0%, ~3%, ~50%, 100% nonzero), precision, radix and thread count.
//!
//! Everything lives in one #[test] because the skip switch is
//! process-global: a single test body flips it deterministically
//! (other test binaries are separate processes and unaffected).

use imagine::analysis::{verify, VerifyCtx};
use imagine::engine::{Engine, EngineConfig};
use imagine::gemv::{plan, GemvProgram};
use imagine::isa::{Instr, Program};
use imagine::pim::alu;
use imagine::util::XorShift;

fn host_gemv(w: &[i64], x: &[i64], m: usize, n: usize) -> Vec<i64> {
    (0..m)
        .map(|r| (0..n).map(|j| w[r * n + j] * x[j]).sum())
        .collect()
}

/// `density_pct`% of entries nonzero (0 = all zero, 100 = none zero).
fn sparse_vec(rng: &mut XorShift, n: usize, half: i64, density_pct: u64) -> Vec<i64> {
    (0..n)
        .map(|_| {
            if density_pct > 0 && (density_pct >= 100 || rng.below(100) < density_pct) {
                loop {
                    let v = rng.range_i64(-half, half - 1);
                    if v != 0 {
                        break v;
                    }
                }
            } else {
                0
            }
        })
        .collect()
}

/// Re-latches the skip switch from `IMAGINE_SKIP` on scope exit, even
/// when an assertion panics mid-sweep.
struct ResetSkip;

impl Drop for ResetSkip {
    fn drop(&mut self) {
        alu::reset_skip();
    }
}

#[test]
fn fused_skip_bit_identical_across_densities() {
    let _reset = ResetSkip;
    let config = EngineConfig::small();
    // (m, n, p, radix, w density %, x density %, threads)
    let cases = [
        (40, 64, 8, 2, 100, 0, 1),
        (40, 64, 8, 2, 100, 3, 4),
        (40, 64, 8, 4, 100, 3, 4),
        (33, 57, 4, 2, 50, 50, 4),
        (33, 57, 4, 4, 3, 100, 1),
        (64, 96, 8, 2, 3, 3, 4),
        (64, 96, 12, 4, 50, 100, 4),
        (16, 16, 2, 2, 100, 100, 1),
        (8, 8, 8, 2, 0, 0, 1),
    ];
    let mut rng = XorShift::new(0x1534_F00D);
    for &(m, n, p, radix, wd, xd, threads) in &cases {
        let tag = format!("m={m} n={n} p={p} r={radix} wd={wd}% xd={xd}% t={threads}");
        let half = 1i64 << (p - 1);
        let w = sparse_vec(&mut rng, m * n, half, wd);
        let x = sparse_vec(&mut rng, n, half, xd);
        let gp = GemvProgram::generate(plan(&config, m, n, p, radix));

        // reference: serial per-instruction interpreter, full-width
        // walks (trace replay is the default tier now — pin it off so
        // both legs exercise the dispatch paths under comparison)
        alu::set_skip(false);
        let mut r_eng = Engine::with_threads(config, 1);
        r_eng.set_fuse(false);
        r_eng.set_trace_mode(false);
        let reference = gp.execute(&mut r_eng, &w, &x).unwrap();

        // optimized: fused kernel replay + occupancy skip, worker pool
        alu::set_skip(true);
        let mut o_eng = Engine::with_threads(config, threads);
        o_eng.set_fuse(true);
        o_eng.set_trace_mode(false);
        let optimized = gp.execute(&mut o_eng, &w, &x).unwrap();

        assert_eq!(optimized.y, reference.y, "y diverged [{tag}]");
        assert_eq!(
            optimized.stats.cycles, reference.stats.cycles,
            "cycle model changed [{tag}]"
        );
        assert_eq!(
            optimized.stats.plane_word_ops, reference.stats.plane_word_ops,
            "work metric changed [{tag}]"
        );
        assert_eq!(optimized.stats, reference.stats, "ExecStats diverged [{tag}]");
        assert_eq!(
            r_eng.columns(),
            o_eng.columns(),
            "column state diverged [{tag}]"
        );
        assert_eq!(reference.y, host_gemv(&w, &x, m, n), "reference wrong [{tag}]");

        // weight-resident replay (the serving fast path) must agree too
        if gp.supports_residency() {
            alu::set_skip(false);
            let hot_ref = gp.execute_opts(&mut r_eng, &w, &x, true).unwrap();
            alu::set_skip(true);
            let hot_opt = gp.execute_opts(&mut o_eng, &w, &x, true).unwrap();
            assert_eq!(hot_opt.y, hot_ref.y, "resident y diverged [{tag}]");
            assert_eq!(hot_opt.stats, hot_ref.stats, "resident stats diverged [{tag}]");
        }
    }
}

/// `k` pre-READ FIFO pops, then a small compute/readout tail.
fn fifo_prog(k: usize) -> Program {
    let mut p = Program::new();
    for _ in 0..k {
        p.push(Instr::rshift());
    }
    p.push(Instr::ldi(1, 7))
        .push(Instr::ldi(2, 9))
        .push(Instr::mult(4, 1, 2))
        .push(Instr::read(4))
        .push(Instr::rshift())
        .seal();
    p
}

/// The fused replay gate (ISSUE 7) admits a kernel only when the live
/// shift FIFO holds at least the verifier's `min_entry_fifo` pre-READ
/// pops. Across the boundary — drain below, at, and past the entry
/// depth — the fused leg must stay bit-identical to the interpreter:
/// same FIFO output, same `ExecStats`, same column state, and the same
/// typed fault when the program over-pops. Doesn't touch the
/// process-global skip switch, so it can ride outside the sweep above.
#[test]
fn fused_replay_gate_matches_interp_at_fifo_boundary() {
    let config = EngineConfig::small();
    let lanes = config.pe_rows();
    let ctx = VerifyCtx::for_engine(&config).with_entry_fifo(None);

    for k in [0, 1, 16, lanes] {
        let prog = fifo_prog(k);
        let report = verify(&prog, &ctx);
        assert!(report.accepts(), "k={k}:\n{report}");
        assert_eq!(report.min_entry_fifo, k, "pre-READ pop count");

        let legs = [false, true].map(|fuse| {
            // pin trace off: this test probes the fused-vs-interp gate
            // itself, and the kernel-cache assert below requires the
            // fused leg to really take the fused path
            let mut e = Engine::with_threads(config, 1);
            e.set_fuse(fuse);
            e.set_trace_mode(false);
            let stats = e.execute(&prog).unwrap();
            (e.drain_fifo(), stats, e)
        });
        let (y_i, stats_i, e_i) = &legs[0];
        let (y_f, stats_f, e_f) = &legs[1];
        assert_eq!(y_f, y_i, "FIFO output diverged [k={k}]");
        assert_eq!(stats_f, stats_i, "ExecStats diverged [k={k}]");
        assert_eq!(stats_f.cycles, report.cost.cycles, "static cycles [k={k}]");
        assert_eq!(e_f.columns(), e_i.columns(), "column state diverged [k={k}]");
        // the fused leg must have actually replayed a kernel (the gate
        // admitted it), visible as a populated kernel cache
        assert_eq!(legs[1].2.kernel_cache_len(), 1, "kernel not cached [k={k}]");
    }

    // one past the entry depth: the verifier still accepts (the entry
    // FIFO is symbolic — min_entry_fifo tells the caller what it
    // needs), the gate routes the run to the interpreter, and both
    // legs fault with the same typed error
    let over = fifo_prog(lanes + 1);
    let report = verify(&over, &ctx);
    assert!(report.accepts());
    assert_eq!(report.min_entry_fifo, lanes + 1);
    // ...and against the *concrete* fresh-engine context it's rejected
    assert!(!verify(&over, &VerifyCtx::for_engine(&config)).accepts());
    for fuse in [false, true] {
        let mut e = Engine::with_threads(config, 1);
        e.set_fuse(fuse);
        e.set_trace_mode(false);
        assert!(e.execute(&over).is_err(), "over-pop must fault [fuse={fuse}]");
    }

    // the gate reads the *live* FIFO depth, not the entry depth: after
    // a run drains all but one entry, a 1-pop kernel still replays and
    // a 2-pop one falls back and faults — identically on both legs
    for fuse in [false, true] {
        let mut e = Engine::with_threads(config, 1);
        e.set_fuse(fuse);
        e.set_trace_mode(false);
        let drain: Program = (0..lanes - 1).map(|_| Instr::rshift()).chain([Instr::halt()]).collect();
        e.execute(&drain).unwrap();
        let one: Program = [Instr::rshift(), Instr::halt()].into_iter().collect();
        assert!(e.execute(&one).is_ok(), "one entry left, one pop [fuse={fuse}]");
        assert!(e.execute(&one).is_err(), "FIFO empty now [fuse={fuse}]");
    }
}
