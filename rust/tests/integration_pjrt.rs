//! Cross-backend integration: the cycle-accurate IMAGine simulator,
//! the host reference, and the PJRT-executed AOT artifacts (L1 Pallas
//! bit-serial kernel inside the L2 JAX graph) must agree bit-for-bit.
//! Requires a build with the `pjrt` feature (against a real xla
//! binding, not the offline stub) and `make artifacts`; skips — never
//! fails — when either is missing. The simulator-vs-simulator backend
//! equivalence lives in `tests/backend_equivalence.rs` and always
//! runs.
#![cfg(feature = "pjrt")]

use imagine::engine::{Engine, EngineConfig};
use imagine::gemv::scheduler::{GemvScheduler, Layer};
use imagine::gemv::{plan, GemvProgram};
use imagine::runtime::Runtime;
use imagine::util::XorShift;
use std::path::{Path, PathBuf};

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Runtime + artifacts, or skip this test.
fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::load(&artifacts()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT integration test: {e}");
            None
        }
    }
}

fn sim_gemv(d: usize, radix: u8, w: &[i64], x: &[i64]) -> Vec<i64> {
    let config = EngineConfig::small();
    let gp = GemvProgram::generate(plan(&config, d, d, 8, radix));
    let mut engine = Engine::new(config);
    gp.execute(&mut engine, w, x).unwrap().y
}

#[test]
fn gemv_artifacts_match_simulator() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = XorShift::new(100);
    for d in [64usize, 128, 256] {
        let w = rng.vec_i64(d * d, -128, 127);
        let x = rng.vec_i64(d, -128, 127);
        let pjrt = rt.gemv_i64(&format!("gemv_{d}x{d}_p8"), &w, &x).unwrap();
        let sim = sim_gemv(d, 2, &w, &x);
        assert_eq!(pjrt, sim, "d={d}");
    }
}

#[test]
fn booth_artifact_matches_booth_simulator() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = XorShift::new(101);
    let d = 256;
    let w = rng.vec_i64(d * d, -128, 127);
    let x = rng.vec_i64(d, -128, 127);
    let pjrt = rt.gemv_i64("gemv_256x256_p8_booth4", &w, &x).unwrap();
    let sim = sim_gemv(d, 4, &w, &x);
    assert_eq!(pjrt, sim);
}

#[test]
fn p4_artifact_matches_simulator() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = XorShift::new(102);
    let d = 256;
    let w = rng.vec_i64(d * d, -8, 7);
    let x = rng.vec_i64(d, -8, 7);
    let pjrt = rt.gemv_i64("gemv_256x256_p4", &w, &x).unwrap();
    let config = EngineConfig::small();
    let gp = GemvProgram::generate(plan(&config, d, d, 4, 2));
    let mut engine = Engine::new(config);
    let sim = gp.execute(&mut engine, &w, &x).unwrap().y;
    assert_eq!(pjrt, sim);
}

#[test]
fn gemm_batch_artifact_matches_per_vector_sim() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = XorShift::new(103);
    let (b, d) = (8usize, 256usize);
    let w = rng.vec_i64(d * d, -128, 127);
    let xs: Vec<Vec<i64>> = (0..b).map(|_| rng.vec_i64(d, -128, 127)).collect();
    let wi: Vec<i32> = w.iter().map(|&v| v as i32).collect();
    let xf: Vec<i32> = xs.iter().flatten().map(|&v| v as i32).collect();
    let out = rt.execute("gemm_b8_256x256_p8", &[&wi, &xf]).unwrap();
    for (i, x) in xs.iter().enumerate() {
        let sim = sim_gemv(d, 2, &w, x);
        let got: Vec<i64> = out[i * d..(i + 1) * d].iter().map(|&v| v as i64).collect();
        assert_eq!(got, sim, "batch row {i}");
    }
}

#[test]
fn mlp_artifact_matches_scheduler() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let dims = [784usize, 256, 128, 10];
    let scales = [0.0078125f64, 0.0078125];
    let mut rng = XorShift::new(104);
    let mut layers = Vec::new();
    let mut flat: Vec<Vec<i32>> = Vec::new();
    for i in 0..3 {
        let (o, n) = (dims[i + 1], dims[i]);
        let w = rng.vec_i64(o * n, -16, 15);
        let b = rng.vec_i64(o, -64, 63);
        flat.push(w.iter().map(|&v| v as i32).collect());
        flat.push(b.iter().map(|&v| v as i32).collect());
        layers.push(Layer::new(w, b, o, n));
    }
    let x = rng.vec_i64(784, -128, 127);
    let xi: Vec<i32> = x.iter().map(|&v| v as i32).collect();
    let ins: Vec<&[i32]> = std::iter::once(xi.as_slice())
        .chain(flat.iter().map(|v| v.as_slice()))
        .collect();
    let pjrt = rt.execute("mlp_b1", &ins).unwrap();

    let mut sched = GemvScheduler::new(EngineConfig::small());
    let (sim, _) = sched.mlp_forward(&layers, &x, &scales, 8, 2).unwrap();
    let sim32: Vec<i32> = sim.iter().map(|&v| v as i32).collect();
    assert_eq!(pjrt, sim32);
}

#[test]
fn runtime_reports_missing_artifacts_dir() {
    assert!(Runtime::load(Path::new("/nonexistent/dir")).is_err());
}
