//! Fleet placement property suite: packing invariants, the typed
//! admission boundary, eviction/re-admission and member-death migration
//! under live serving, and bit-identity of fleet dispatch against the
//! legacy (pure name-hash) policy.
//!
//! The capacity model under test is two-level (docs/PLACEMENT.md):
//! registration-level *reservations* (what `CapacityExceeded` guards;
//! only `unregister` frees them) and placement-level *residency* (what
//! LRU eviction moves around; evicted models re-admit transparently on
//! their next dispatch). Every serving assertion below also checks
//! results stay bit-identical to the host reference — placement decides
//! where a model runs, never what it computes.

use imagine::coordinator::{
    BackendPolicy, BatchPolicy, Coordinator, CoordinatorConfig, FleetConfig, ModelRegistry,
    ModelSpec, PlacementMode, RegistryError, Request, SubmitError,
};
use imagine::engine::EngineConfig;
use imagine::gemv::mapper::{member_capacity_bits, weight_footprint_bits};
use imagine::placement::FleetPlanner;
use imagine::sim::fault::{self, FaultPlan};
use imagine::util::XorShift;
use std::time::Duration;

fn host_gemv(w: &[i64], x: &[i64], m: usize, n: usize) -> Vec<i64> {
    (0..m)
        .map(|r| (0..n).map(|j| w[r * n + j] * x[j]).sum())
        .collect()
}

fn coord_cfg(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        batch: BatchPolicy::none(),
        backend: BackendPolicy::Auto,
        ..Default::default()
    }
}

/// Packing property under a randomized admit/touch/release churn: no
/// member ever exceeds its budget, per-member used bits always equal
/// the sum of its placed models' bits, and the reservation total always
/// equals the sum of registered footprints (eviction frees placement,
/// never reservations).
#[test]
fn packing_invariants_hold_under_random_churn() {
    let budget = weight_footprint_bits(100, 8);
    let planner = FleetPlanner::with_config(FleetConfig {
        members: 3,
        member_budget_bits: Some(budget),
        ..FleetConfig::default()
    });
    let mut rng = XorShift::new(0xF1EE7);
    let mut live: Vec<(u64, u64)> = Vec::new(); // (id, bits)
    let mut next_id = 1u64;
    for step in 0..400 {
        match rng.below(4) {
            // admit a random model (sometimes too big for any member:
            // a tracking planner leaves it unplaced, never denies)
            0 | 1 => {
                let elems = 10 + rng.below(120);
                planner
                    .admit(next_id, &format!("m{next_id}"), elems, 8)
                    .unwrap();
                live.push((next_id, weight_footprint_bits(elems, 8)));
                next_id += 1;
            }
            // serve (touch) a random live model: evicted ones re-place
            2 if !live.is_empty() => {
                let (id, _) = live[rng.below(live.len() as u64) as usize];
                planner.touch(id);
            }
            // unregister a random live model
            _ if !live.is_empty() => {
                let i = rng.below(live.len() as u64) as usize;
                let (id, _) = live.swap_remove(i);
                planner.release(id);
            }
            _ => {}
        }
        let plan = planner.plan();
        for m in &plan.members {
            assert!(
                m.used_bits <= m.budget_bits,
                "step {step}: member {} over budget: {plan:?}",
                m.index
            );
            let placed: u64 = m.models.iter().map(|pm| pm.bits).sum();
            assert_eq!(placed, m.used_bits, "step {step}: used-bits drift: {plan:?}");
        }
        let expect_reserved: u64 = live.iter().map(|(_, b)| b).sum();
        assert_eq!(plan.reserved_bits, expect_reserved, "step {step}");
        let accounted = plan.members.iter().map(|m| m.models.len()).sum::<usize>()
            + plan.unplaced.len();
        assert_eq!(accounted, live.len(), "step {step}: model lost by the plan");
    }
}

/// The typed admission boundary is exact: an enforcing fleet admits up
/// to the aggregate, denies past it with the precise
/// requested/available bit counts (a denial leaks no reservation), and
/// `unregister` eagerly frees budget that then admits a *larger* model
/// than the one removed (the satellite regression: release must not be
/// deferred to pool-slot reuse).
#[test]
fn admission_boundary_is_exact_and_unregister_frees_budget() {
    // one member of exactly 100 8-bit elements (1600 bits)
    let budget = weight_footprint_bits(100, 8);
    let reg = ModelRegistry::default().with_fleet(FleetConfig {
        members: 1,
        member_budget_bits: Some(budget),
        enforce: true,
        ..FleetConfig::default()
    });
    // 40 + 40 elems reserve 80 of the 100
    reg.register("a", ModelSpec::gemv(vec![1; 40], 8, 5)).unwrap();
    reg.register("c", ModelSpec::gemv(vec![1; 40], 5, 8)).unwrap();
    // 50 elems against the 20 remaining: denied with exact counts
    let err = reg
        .register("b", ModelSpec::gemv(vec![1; 50], 10, 5))
        .unwrap_err();
    assert_eq!(
        err,
        RegistryError::CapacityExceeded {
            requested_bits: weight_footprint_bits(50, 8),
            available_bits: weight_footprint_bits(20, 8),
        }
    );
    // regression: unregister the 40-elem model, then admit a *larger*
    // one (55 elems) into the freed budget — and the earlier denial
    // must not have leaked any reservation
    reg.unregister("a").unwrap();
    reg.register("big", ModelSpec::gemv(vec![1; 55], 5, 11)).unwrap();
    assert!(reg.get("big").is_ok());
    // precision rides the spec into the footprint: 5 elems remain
    // (80 bits); a 2x4 model at the default 8 bits is 128 bits (denied)
    // but at 4 bits is 64 bits (admitted)
    let err = reg
        .register("q8", ModelSpec::gemv(vec![1; 8], 2, 4))
        .unwrap_err();
    assert!(matches!(err, RegistryError::CapacityExceeded { .. }), "{err:?}");
    reg.register("q4", ModelSpec::gemv(vec![1; 8], 2, 4).precision(4))
        .unwrap();
}

/// Eviction/re-admission is transparent and bit-identical: two models
/// that can never cohabit on the single member alternate requests, so
/// every dispatch re-places the evicted one — and every response still
/// matches the host reference exactly.
#[test]
fn eviction_and_readmission_stay_bit_identical() {
    let (m, n) = (16, 16);
    // budget = exactly one 16x16 model's footprint
    let budget = weight_footprint_bits((m * n) as u64, 8);
    let mut rng = XorShift::new(0xE41C7);
    let wa = rng.vec_i64(m * n, -16, 15);
    let wb = rng.vec_i64(m * n, -16, 15);
    let reg = ModelRegistry::default().with_fleet(FleetConfig {
        members: 1,
        member_budget_bits: Some(budget),
        enforce: false, // reservation-over-budget is fine; placement churns
        ..FleetConfig::default()
    });
    reg.register("a", ModelSpec::gemv(wa.clone(), m, n)).unwrap();
    reg.register("b", ModelSpec::gemv(wb.clone(), m, n)).unwrap();
    let coord = Coordinator::start(coord_cfg(1), reg);
    for round in 0..4 {
        let x = rng.vec_i64(n, -64, 63);
        let ra = coord.call(Request::new("a", x.clone())).unwrap();
        assert_eq!(ra.y, host_gemv(&wa, &x, m, n), "round {round}");
        let rb = coord.call(Request::new("b", x.clone())).unwrap();
        assert_eq!(rb.y, host_gemv(&wb, &x, m, n), "round {round}");
    }
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.failed, 0);
    // the alternation forced placement churn, visible in the lifecycle
    // counters the coordinator folds in from the planner
    assert!(snap.evictions >= 2, "{snap:?}");
    assert!(snap.readmissions >= 1, "{snap:?}");
}

/// Member-death migration: a seeded worker panic (`panic:group=0`)
/// kills the model's home member mid-request; the next request marks
/// the member dead at dispatch, migrates the model to the survivor, and
/// serves bit-identical results there.
#[test]
fn member_death_migrates_and_serves_on_survivor() {
    let _guard = fault::install_scoped(FaultPlan {
        panics: vec![0],
        seed: 23,
        ..Default::default()
    });
    let (m, n) = (16, 16);
    let mut rng = XorShift::new(0xDEAD1);
    let w = rng.vec_i64(m * n, -16, 15);
    // explicit fleet shape: the model is placed at registration, so its
    // home member is known before the coordinator starts
    let reg = ModelRegistry::default()
        .with_fleet(FleetConfig { members: 2, ..FleetConfig::default() });
    reg.register("m", ModelSpec::gemv(w.clone(), m, n)).unwrap();
    let id = reg.get("m").unwrap().id();
    let coord = Coordinator::start(coord_cfg(2), reg);
    let home = coord.fleet().planner().home(id).expect("placed at registration");
    // first request: its group is ordinal 0, the worker panics and the
    // reply channel drops
    let err = coord.call(Request::new("m", vec![1; n])).unwrap_err();
    assert!(matches!(err, SubmitError::WorkerLost), "{err:?}");
    // second request: submit finds the dead queue, marks the member
    // down, and re-dispatches — served exactly, from the survivor
    let x = rng.vec_i64(n, -64, 63);
    let resp = coord.call(Request::new("m", x.clone())).unwrap();
    assert_eq!(resp.y, host_gemv(&w, &x, m, n));
    let planner = coord.fleet().planner().clone();
    assert!(!planner.is_alive(home), "home member must be quarantined");
    let new_home = planner.home(id).expect("re-placed on a survivor");
    assert_ne!(new_home, home, "model must migrate off the dead member");
    let snap = coord.shutdown();
    assert!(snap.migrations >= 1, "{snap:?}");
    assert!(snap.readmissions >= 1, "{snap:?}");
}

/// Legacy-vs-fleet bit-identity: the same request stream served by a
/// fleet-dispatch coordinator and a legacy (pure name-hash) one returns
/// identical vectors — placement moves models between members, it never
/// changes arithmetic.
#[test]
fn fleet_and_legacy_dispatch_are_bit_identical() {
    let mut rng = XorShift::new(0x1DE57);
    let shapes = [(16usize, 16usize), (48, 64), (768, 48)];
    let weights: Vec<Vec<i64>> =
        shapes.iter().map(|&(m, n)| rng.vec_i64(m * n, -16, 15)).collect();
    let build = |mode: PlacementMode| {
        let reg = ModelRegistry::default().with_fleet(FleetConfig {
            members: 2,
            mode,
            ..FleetConfig::default()
        });
        for (i, (&(m, n), w)) in shapes.iter().zip(&weights).enumerate() {
            reg.register(&format!("m{i}"), ModelSpec::gemv(w.clone(), m, n))
                .unwrap();
        }
        Coordinator::start(coord_cfg(2), reg)
    };
    let fleet = build(PlacementMode::Fleet);
    let legacy = build(PlacementMode::Legacy);
    for round in 0..3 {
        for (i, &(m, n)) in shapes.iter().enumerate() {
            let x = rng.vec_i64(n, -64, 63);
            let name = format!("m{i}");
            let yf = fleet.call(Request::new(name.clone(), x.clone())).unwrap().y;
            let yl = legacy.call(Request::new(name, x.clone())).unwrap().y;
            assert_eq!(yf, yl, "round {round}, model m{i}");
            assert_eq!(yf, host_gemv(&weights[i], &x, m, n), "round {round}");
        }
    }
    let (sf, sl) = (fleet.shutdown(), legacy.shutdown());
    assert_eq!(sf.completed, 9);
    assert_eq!(sl.completed, 9);
    assert_eq!((sf.failed, sl.failed), (0, 0));
}

/// The router-drift regression at fleet scope: a shed-heavy workload
/// (deadlines already expired at scheduling) must leave every member's
/// outstanding-load counter at zero once the replies are observed — the
/// old manual accounting leaked one slot per shed group forever.
#[test]
fn shed_heavy_load_leaves_zero_outstanding_load() {
    let (m, n) = (8, 8);
    let mut rng = XorShift::new(0x5EED);
    let w = rng.vec_i64(m * n, -16, 15);
    let reg = ModelRegistry::default();
    reg.register("g", ModelSpec::gemv(w.clone(), m, n)).unwrap();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            batch: BatchPolicy { max_batch: 4, window: Duration::from_millis(20) },
            ..Default::default()
        },
        reg,
    );
    // a batch-window's worth of requests with microscopic deadlines:
    // all shed before execution
    let rxs: Vec<_> = (0..8)
        .map(|_| {
            coord
                .submit(Request::new("g", vec![1; n]).with_deadline_us(1))
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let err = rx.recv().unwrap().unwrap_err();
        assert!(matches!(err, SubmitError::DeadlineExceeded { .. }), "{err:?}");
    }
    // load-zero is observable as soon as the replies are: the tokens
    // were taken before each send
    for wid in 0..2 {
        assert_eq!(coord.fleet().load(wid), 0, "member {wid} leaked load");
    }
    // ...and the pool still serves normally afterwards
    let x = rng.vec_i64(n, -64, 63);
    let resp = coord.call(Request::new("g", x.clone())).unwrap();
    assert_eq!(resp.y, host_gemv(&w, &x, m, n));
    let snap = coord.shutdown();
    assert_eq!(snap.deadline_misses, 8, "{snap:?}");
    assert_eq!(snap.completed, 1);
}

/// The acceptance scenario: a model set whose aggregate footprint
/// exceeds ONE member's capacity (the old per-worker private-pool
/// ceiling) but fits the two-member fleet registers is admitted, placed
/// one model per member, and serves resident; a third model over the
/// aggregate is denied typed with the exact remaining budget.
#[test]
fn model_set_over_one_member_fits_the_fleet_and_serves_resident() {
    let engine = EngineConfig::single_tile();
    let member_bits = member_capacity_bits(&engine);
    let (m, n) = (450, 450);
    let model_bits = weight_footprint_bits((m * n) as u64, 8);
    // two models exceed one member but fit the two-member aggregate;
    // three exceed the aggregate
    assert!(model_bits < member_bits && 2 * model_bits > member_bits);
    assert!(3 * model_bits > 2 * member_bits);
    let mut rng = XorShift::new(0xACCE);
    let wa = rng.vec_i64(m * n, -8, 7);
    let wb = rng.vec_i64(m * n, -8, 7);
    let reg = ModelRegistry::default().with_fleet(FleetConfig::enforced(2, engine));
    reg.register("a", ModelSpec::gemv(wa.clone(), m, n)).unwrap();
    reg.register("b", ModelSpec::gemv(wb.clone(), m, n)).unwrap();
    let err = reg
        .register("c", ModelSpec::gemv(vec![0; m * n], m, n))
        .unwrap_err();
    assert_eq!(
        err,
        RegistryError::CapacityExceeded {
            requested_bits: model_bits,
            available_bits: 2 * member_bits - 2 * model_bits,
        }
    );
    let coord = Coordinator::start(
        CoordinatorConfig { engine, ..coord_cfg(2) },
        reg,
    );
    let plan = coord.fleet_plan();
    assert_eq!(plan.unplaced.len(), 0, "{plan:?}");
    assert!(
        plan.members.iter().all(|mb| mb.models.len() == 1),
        "one model per member: {plan:?}"
    );
    // both serve bit-identically, and repeat requests hit residency
    for round in 0..2 {
        let x = rng.vec_i64(n, -16, 15);
        let ra = coord.call(Request::new("a", x.clone())).unwrap();
        assert_eq!(ra.y, host_gemv(&wa, &x, m, n), "round {round}");
        let rb = coord.call(Request::new("b", x.clone())).unwrap();
        assert_eq!(rb.y, host_gemv(&wb, &x, m, n), "round {round}");
    }
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 4);
    assert_eq!(snap.failed, 0);
    // the second round's groups arrive with their member's shard pool
    // already staged
    assert!(snap.residency_hits >= 2, "{snap:?}");
    // two ~0.69-member models placed: occupancy is well past half
    assert!(snap.fleet_occupancy_milli > 600, "{snap:?}");
}
