//! Property-based invariants (in-repo `run_prop` driver — proptest is
//! unavailable offline): bit-plane ALU == two's-complement arithmetic,
//! ISA encode/decode total, mapper coverage, GEMV == host reference,
//! coordinator request/response integrity.

use imagine::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, ModelRegistry, Request};
use imagine::engine::{Engine, EngineConfig, SEL_ALL};
use imagine::gemv::{plan, GemvProgram, MappingPlan};
use imagine::isa::{Instr, Opcode, Program, RawInstr};
use imagine::pim::{alu, PlaneBuf};
use imagine::util::rng::{run_prop, XorShift};

#[test]
fn prop_bitplane_add_sub_exact() {
    run_prop("add/sub == i64", 40, |rng| {
        let lanes = rng.range(1, 300);
        let wa = rng.range(2, 16);
        let wb = rng.range(2, 16);
        let wd = rng.range(wa.max(wb), 33);
        let mut b = PlaneBuf::new(128, lanes);
        let av = rng.vec_i64(lanes, -(1 << (wa - 1)), (1 << (wa - 1)) - 1);
        let bv = rng.vec_i64(lanes, -(1 << (wb - 1)), (1 << (wb - 1)) - 1);
        b.write_all(0, wa, &av);
        b.write_all(16, wb, &bv);
        let sub = rng.bool();
        alu::add_sub(&mut b, (40, wd), (0, wa), (16, wb), sub);
        let got = b.read_all(40, wd);
        for l in 0..lanes {
            let want = if sub { av[l] - bv[l] } else { av[l] + bv[l] };
            // result is exact when it fits wd bits
            if want >= -(1 << (wd - 1)) && want < (1 << (wd - 1)) {
                assert_eq!(got[l], want, "lane {l} wa={wa} wb={wb} wd={wd} sub={sub}");
            }
        }
    });
}

#[test]
fn prop_bitplane_mac_exact() {
    run_prop("mac == i64 (both radices)", 30, |rng| {
        let lanes = rng.range(1, 200);
        let p = rng.range(2, 12);
        let half = 1i64 << (p - 1);
        let mut b = PlaneBuf::new(128, lanes);
        let wv = rng.vec_i64(lanes, -half, half - 1);
        let xv = rng.vec_i64(lanes, -half, half - 1);
        let acc0 = rng.vec_i64(lanes, -(1 << 20), 1 << 20);
        b.write_all(0, p, &wv);
        b.write_all(16, p, &xv);
        b.write_all(48, 32, &acc0);
        if rng.bool() {
            alu::mac_radix2(&mut b, (48, 32), (0, p), (16, p), false);
        } else {
            alu::mac_booth4(&mut b, (48, 32), (0, p), (16, p), false);
        }
        let got = b.read_all(48, 32);
        for l in 0..lanes {
            assert_eq!(got[l], acc0[l] + wv[l] * xv[l], "lane {l} p={p}");
        }
    });
}

#[test]
fn prop_isa_decode_total() {
    run_prop("decode(encode(i)) == i, decode never panics", 200, |rng| {
        // round-trip of arbitrary valid instructions
        let i = Instr::new(
            *rng.pick(&imagine::isa::Opcode::ALL),
            rng.range(0, 31) as u8,
            rng.range(0, 31) as u8,
            rng.range(0, 31) as u8,
            rng.range(0, 1023) as u16,
        );
        assert_eq!(Instr::decode(i.encode()).unwrap(), i);
        // arbitrary 32-bit words either decode or error, never panic
        let raw = RawInstr(rng.next_u64() as u32);
        let _ = Instr::decode(raw);
    });
}

#[test]
fn prop_mapping_covers_matrix() {
    run_prop("mapping covers every column exactly", 60, |rng| {
        let config = EngineConfig::u55();
        let m = rng.range(1, 4000);
        let n = rng.range(1, 4000);
        let p = *rng.pick(&[2usize, 4, 8, 16]);
        let pl = plan(&config, m, n, p, if rng.bool() { 2 } else { 4 });
        // capacity
        assert!(pl.k_per_pe <= MappingPlan::k_max(p), "{pl:?}");
        // coverage
        let chunks = pl.cols_used * pl.fold_factor;
        assert!(chunks * pl.k_per_pe * pl.chunk_passes >= n, "{pl:?}");
        assert!(pl.row_passes * config.pe_rows() >= m, "{pl:?}");
        // replicas fit in the array (spacing only meaningful with folds)
        if pl.fold_factor > 1 {
            assert!(pl.fold_factor * pl.replica_spacing() <= config.pe_rows(), "{pl:?}");
        }
        // accumulator wide enough for the worst dot product
        let worst = (n as f64).log2() + 2.0 * p as f64;
        assert!(pl.acc_width as f64 + 1.0 >= worst.min(64.0), "{pl:?}");
    });
}

#[test]
fn prop_gemv_simulator_exact() {
    run_prop("simulated GEMV == host reference", 12, |rng| {
        let m = rng.range(1, 96);
        let n = rng.range(1, 96);
        let p = *rng.pick(&[4usize, 8]);
        let radix = if rng.bool() { 2 } else { 4 };
        let half = 1i64 << (p - 1);
        let config = EngineConfig::small();
        let gp = GemvProgram::generate(plan(&config, m, n, p, radix));
        let mut engine = Engine::new(config);
        let w = rng.vec_i64(m * n, -half, half - 1);
        let x = rng.vec_i64(n, -half, half - 1);
        let res = gp.execute(&mut engine, &w, &x).unwrap();
        let host: Vec<i64> = (0..m)
            .map(|r| (0..n).map(|j| w[r * n + j] * x[j]).sum())
            .collect();
        assert_eq!(res.y, host, "m={m} n={n} p={p} radix={radix}");
    });
}

#[test]
fn prop_coordinator_preserves_request_response_mapping() {
    // Every submitted request gets exactly its own answer, regardless
    // of batching, worker count, or model mix.
    let mut rng = XorShift::new(1234);
    let reg = ModelRegistry::default();
    let w1 = rng.vec_i64(8 * 8, -32, 31);
    let w2 = rng.vec_i64(4 * 8, -32, 31);
    reg.register_gemv("a", w1.clone(), 8, 8).unwrap();
    reg.register_gemv("b", w2.clone(), 4, 8).unwrap();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 3,
            batch: BatchPolicy { max_batch: 4, ..Default::default() },
            ..Default::default()
        },
        reg,
    );
    let host = |w: &[i64], x: &[i64], m: usize| -> Vec<i64> {
        (0..m).map(|r| (0..8).map(|j| w[r * 8 + j] * x[j]).sum()).collect()
    };
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..60 {
        let x = rng.vec_i64(8, -64, 63);
        let (model, m, w) = if i % 2 == 0 { ("a", 8, &w1) } else { ("b", 4, &w2) };
        expected.push(host(w, &x, m));
        rxs.push(coord.submit(Request::new(model, x)).unwrap());
    }
    for (want, rx) in expected.into_iter().zip(rxs) {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.y, want);
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 60);
    assert_eq!(m.submitted, 60);
    assert_eq!(m.failed, 0);
}

/// A random but always-valid instruction stream over the full ISA's
/// data ops (MAC trio kept at the codegen register convention so the
/// operand windows never alias).
fn random_program(rng: &mut XorShift, cols: usize) -> Program {
    let mut prog = Program::new();
    prog.push(Instr::setp(0, 8)); // precision
    prog.push(Instr::setp(1, 32)); // acc width
    prog.push(Instr::setp(2, if rng.bool() { 4 } else { 2 })); // radix
    for _ in 0..rng.range(8, 20) {
        let i = match rng.below(10) {
            0 => Instr::ldi(rng.range(0, 7) as u8, rng.below(1024) as u16),
            1 => Instr::write(rng.range(0, 7) as u8, 0),
            2 => Instr::mov(rng.range(0, 6) as u8, rng.range(0, 6) as u8),
            3 => Instr::add(rng.range(0, 6) as u8, rng.range(0, 6) as u8, rng.range(0, 6) as u8),
            4 => Instr::sub(rng.range(0, 6) as u8, rng.range(0, 6) as u8, rng.range(0, 6) as u8),
            // imm > 0 exercises the spill-pointer staging inside the
            // parallel dispatch
            5 => Instr::new(Opcode::Mult, 4, 1, 2, rng.below(4) as u16),
            6 => Instr::new(Opcode::Mac, 4, 1, 2, rng.below(4) as u16),
            7 => Instr::selblk(if rng.bool() { SEL_ALL } else { rng.below(cols as u64) as u16 }),
            8 => Instr::accum(4, rng.range(1, 3) as u16),
            _ => Instr::fold(4, rng.range(0, 2) as u16),
        };
        prog.push(i);
    }
    prog.push(Instr::selblk(SEL_ALL));
    prog.push(Instr::read(4));
    for _ in 0..4 {
        prog.push(Instr::rshift());
    }
    prog.seal();
    prog
}

#[test]
fn prop_column_parallel_engine_bit_identical_to_serial() {
    // The tentpole invariant: the column-parallel dispatch must produce
    // bit-identical column state, FIFO output and identical ExecStats
    // (cycles included) to a forced single-thread engine, across random
    // programs. Lanes are sized so the parallel path actually engages
    // (4608 lanes x 4 columns is past the dispatch threshold).
    run_prop("column-parallel == serial", 6, |rng| {
        let config = EngineConfig { tile_rows: 24, tile_cols: 2, ..EngineConfig::u55() };
        let mut serial = Engine::with_threads(config, 1);
        let mut parallel = Engine::with_threads(config, 4);
        assert_eq!(serial.threads(), 1);
        let lanes = serial.pe_rows();
        let cols = serial.block_cols();
        for c in 0..cols {
            for reg in [0u8, 1, 2, 4, 6] {
                let v = rng.vec_i64(lanes, -100_000, 100_000);
                serial.write_reg_lanes(c, reg, 32, &v).unwrap();
                parallel.write_reg_lanes(c, reg, 32, &v).unwrap();
            }
            for idx in 0..8 {
                let v = rng.vec_i64(lanes, -128, 127);
                serial.write_spill(c, 8, 8, idx, &v);
                parallel.write_spill(c, 8, 8, idx, &v);
            }
        }
        let prog = random_program(rng, cols);
        let s1 = serial.execute(&prog).unwrap();
        let s2 = parallel.execute(&prog).unwrap();
        assert_eq!(s1, s2, "ExecStats must match cycle-for-cycle");
        assert_eq!(serial.columns(), parallel.columns(), "column state diverged");
        assert_eq!(serial.drain_fifo(), parallel.drain_fifo());
    });
}

#[test]
fn prop_fused_engine_bit_identical_to_interpreter() {
    // The compiled-kernel tentpole invariant: lowering a program once
    // and replaying it (one pool dispatch per segment) must produce
    // bit-identical column state, FIFO output and identical ExecStats
    // to the per-instruction interpreter, across random programs AND
    // across HALT boundaries (Op-Params, SELBLK and the LDI staging
    // register persist between streams and parameterize the lowering).
    run_prop("fused == interpreter", 6, |rng| {
        let config = EngineConfig { tile_rows: 24, tile_cols: 2, ..EngineConfig::u55() };
        // pin the default-on trace tier off on both legs: the property
        // compares the two dispatch paths underneath it
        let mut interp = Engine::with_threads(config, 4);
        interp.set_fuse(false);
        interp.set_trace_mode(false);
        let mut fused = Engine::with_threads(config, 4);
        fused.set_fuse(true);
        fused.set_trace_mode(false);
        let lanes = interp.pe_rows();
        let cols = interp.block_cols();
        for c in 0..cols {
            for reg in [0u8, 1, 2, 4, 6] {
                let v = rng.vec_i64(lanes, -100_000, 100_000);
                interp.write_reg_lanes(c, reg, 32, &v).unwrap();
                fused.write_reg_lanes(c, reg, 32, &v).unwrap();
            }
            for idx in 0..8 {
                let v = rng.vec_i64(lanes, -128, 127);
                interp.write_spill(c, 8, 8, idx, &v);
                fused.write_spill(c, 8, 8, idx, &v);
            }
        }
        // two consecutive streams exercise cross-program entry state
        for stream in 0..2 {
            let prog = random_program(rng, cols);
            let s1 = interp.execute(&prog).unwrap();
            let s2 = fused.execute(&prog).unwrap();
            assert_eq!(s1, s2, "ExecStats diverged (stream {stream})");
        }
        assert_eq!(interp.columns(), fused.columns(), "column state diverged");
        assert_eq!(interp.drain_fifo(), fused.drain_fifo());
    });
}

#[test]
fn prop_fold_preserves_sum() {
    run_prop("fold network conserves the column sum", 30, |rng| {
        let lanes = 256;
        let mut b = PlaneBuf::new(64, lanes);
        let v = rng.vec_i64(lanes, -1000, 1000);
        b.write_all(0, 32, &v);
        let group = 16usize << rng.range(0, 3);
        alu::fold_step(&mut b, 0, 32, group);
        let got = b.read_all(0, 32);
        // each surviving group head holds its pair sum
        for l in 0..lanes - group {
            assert_eq!(got[l], v[l] + v[l + group], "lane {l} group {group}");
        }
    });
}
