//! Offline API stub for the `xla` PJRT bindings.
//!
//! This build environment has no network and no XLA shared libraries,
//! so the real binding cannot be vendored. This crate reproduces
//! exactly the API surface `imagine::runtime::pjrt` uses — enough for
//! the `pjrt` feature to *type-check* everywhere (keeping the feature
//! gate honest under `cargo check --all-features`) while every client
//! entry point returns a typed [`Error`]. Because the one constructor
//! ([`PjRtClient::cpu`]) always fails, no other method can ever be
//! reached at runtime; their bodies are unreachable by construction.
//!
//! To execute PJRT for real, point the `xla` dependency in
//! `rust/Cargo.toml` at a real binding with this surface:
//!
//! ```toml
//! xla = { path = "/path/to/xla-rs", optional = true }
//! ```
//!
//! Zero dependencies by design (the workspace builds offline).

use std::fmt;

/// The binding-level error type (`RuntimeError::Xla` wraps it).
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn stub() -> Error {
        Error("xla stub: real PJRT binding not linked (see rust/vendor/xla-stub)".into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// A PJRT client handle. The stub's only constructor fails, so no
/// instance ever exists at runtime.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable!("stub PjRtClient cannot be constructed")
    }
}

/// A parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("stub PjRtLoadedExecutable cannot be constructed")
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable!("stub PjRtBuffer cannot be constructed")
    }
}

/// A host literal (typed dense array).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructor_reports_stub() {
        let e = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(e.to_string().contains("stub"), "{e}");
    }

    #[test]
    fn literal_pipeline_reports_stub() {
        // the literal staging path runs before any client call in
        // Runtime::execute; it must fail typed, not panic
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[2]).is_err());
        assert!(HloModuleProto::from_text_file("/nope").is_err());
    }
}
