//! Batched serving demo: a stream of GEMV requests against two
//! registered models, served by the coordinator with dynamic batching;
//! reports throughput, latency percentiles and batching efficiency,
//! plus a no-batching ablation.
//!
//! Run: `cargo run --release --example serve_batch`

use imagine::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, ModelRegistry, Request};
use imagine::util::XorShift;
use std::time::Instant;

fn run(policy: BatchPolicy, label: &str) {
    let mut rng = XorShift::new(99);
    let reg = ModelRegistry::default();
    reg.register_gemv("encoder", rng.vec_i64(128 * 64, -32, 31), 128, 64).unwrap();
    reg.register_gemv("decoder", rng.vec_i64(64 * 128, -32, 31), 64, 128).unwrap();

    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, batch: policy, ..Default::default() },
        reg,
    );
    let requests = 128;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            let (model, n) = if i % 3 == 0 { ("decoder", 128) } else { ("encoder", 64) };
            coord
                .submit(Request { model: model.into(), x: rng.vec_i64(n, -64, 63) })
                .unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.shutdown();
    println!(
        "{label:<12} {requests} reqs in {:>7.1} ms  ({:>7.0} req/s)  batches={:<4} mean_batch={:<5.2} p50={:>4}us p99={:>5}us",
        wall * 1e3,
        requests as f64 / wall,
        m.batches,
        m.mean_batch_size(),
        m.latency_percentile_us(50.0),
        m.latency_percentile_us(99.0),
    );
    assert_eq!(m.completed, requests as u64);
    assert_eq!(m.failed, 0);
}

fn main() {
    println!("== coordinator serving demo: 2 models, 2 workers ==\n");
    run(BatchPolicy::default(), "batched");
    run(BatchPolicy::none(), "unbatched");
    println!("\nbatching amortizes program staging across co-batched requests");
    println!("(the hardware analogue: weights stay resident in BRAM).");
}
