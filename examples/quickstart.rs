//! Quickstart: simulate a 64x64 int8 GEMV on IMAGine, check it against
//! the host reference — and, when built with the `pjrt` feature, the
//! PJRT-executed AOT artifact (the L2 JAX graph lowered once at build
//! time) — and report the modeled latency at the paper's 737 MHz
//! system clock.
//!
//! Run: `cargo run --release --example quickstart`
//! (PJRT leg: `make artifacts`, then add `--features pjrt`.)

use imagine::engine::{Engine, EngineConfig};
use imagine::gemv::{plan, GemvProgram};
#[cfg(feature = "pjrt")]
use imagine::runtime::Runtime;
use imagine::sim::U55_FMAX_MHZ;
use imagine::util::XorShift;
#[cfg(feature = "pjrt")]
use std::path::Path;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let (m, n, p) = (64, 64, 8);
    println!("== IMAGine quickstart: {m}x{n} GEMV @ {p}-bit ==");

    // 1. random int8 operands
    let mut rng = XorShift::new(2024);
    let w = rng.vec_i64(m * n, -128, 127);
    let x = rng.vec_i64(n, -128, 127);

    // 2. map + compile + simulate on the U55 engine geometry
    let config = EngineConfig::u55();
    let pl = plan(&config, m, n, p, 2);
    println!(
        "mapping: {} block cols x fold {}, {} elem/PE, {} active rows",
        pl.cols_used, pl.fold_factor, pl.k_per_pe, pl.active_rows
    );
    let prog = GemvProgram::generate(pl);
    let mut engine = Engine::new(config);
    let res = prog.execute(&mut engine, &w, &x)?;
    println!(
        "simulated: {} cycles = {:.3} us @ {:.0} MHz (fill latency {})",
        res.stats.cycles,
        res.stats.exec_us(U55_FMAX_MHZ),
        U55_FMAX_MHZ,
        res.stats.fill_latency,
    );

    // 3. host reference
    let host: Vec<i64> = (0..m)
        .map(|r| (0..n).map(|j| w[r * n + j] * x[j]).sum())
        .collect();
    assert_eq!(res.y, host, "simulator vs host reference");
    println!("host reference ......... OK");

    // 4. PJRT golden artifact (bit-serial Pallas kernel, AOT-lowered)
    #[cfg(feature = "pjrt")]
    {
        let mut rt = Runtime::load(Path::new("artifacts"))?;
        let y = rt.gemv_i64("gemv_64x64_p8", &w, &x)?;
        assert_eq!(res.y, y, "simulator vs PJRT artifact");
        println!("PJRT artifact ({}) ... OK", rt.platform());
        println!("\nall three backends agree bit-for-bit.");
    }
    #[cfg(not(feature = "pjrt"))]
    println!("\nsimulator and host agree bit-for-bit (PJRT leg needs --features pjrt).");
    Ok(())
}
