//! Device-scaling study (paper §V-B / Fig 4): instantiate the
//! 100%-BRAM IMAGine build on every Table IV device, report PEs and
//! utilization, and confirm the paper's scaling claims.
//!
//! Run: `cargo run --release --example device_scaling`

use imagine::resources::{engine_utilization, DEVICES, SynthMode};
use imagine::tile::TileGeom;

fn main() {
    println!("== IMAGine 100%-BRAM scaling across Virtex-7 / UltraScale+ ==\n");
    let tile = TileGeom::u55();
    println!(
        "{:<6} {:>6} {:>8} {:>7} {:>7} {:>9} {:>7}",
        "ID", "tiles", "PEs", "LUT%", "FF%", "CtrlSet%", "BRAM%"
    );
    let mut all_fit = true;
    for d in &DEVICES {
        let u = engine_utilization(d, &tile, SynthMode::Relaxed);
        all_fit &= u.lut_pct < 100.0 && u.bram_pct > 98.0;
        println!(
            "{:<6} {:>6} {:>8} {:>7.1} {:>7.1} {:>9.1} {:>7.1}",
            u.device_id, u.tiles, u.pes, u.lut_pct, u.ff_pct, u.ctrl_set_pct, u.bram_pct
        );
    }
    println!();
    assert!(all_fit);
    println!("every device reaches ~100% BRAM-as-PIM with logic to spare —");
    println!("\"IMAGine is scalable up to 100% BRAM capacity irrespective of");
    println!("the available logic resources in existing devices\" (§V-B).");

    // the final (timing-closed) U55 numbers, Table V row
    let u55 = imagine::resources::device_by_id("U55").unwrap();
    let f = engine_utilization(u55, &tile, SynthMode::Final);
    println!(
        "\nfinal U55 build: {} PEs, {:.1}% LUT, {:.1}% FF, {:.0}% BRAM @ 737 MHz",
        f.pes, f.lut_pct, f.ff_pct, f.bram_pct
    );
}
