//! End-to-end driver (DESIGN.md §6): serve a 784-256-128-10 int8 MLP
//! digit classifier through the coordinator on simulated IMAGine
//! engines, over a synthetic digit workload, cross-checking numerics
//! against the PJRT-executed AOT artifact (`mlp_b1`) and reporting
//! modeled-hardware latency/throughput at 737 MHz.
//!
//! This exercises every layer of the stack in one run:
//!   L1 Pallas bit-serial kernel  -> inside the AOT artifact
//!   L2 JAX MLP graph             -> artifacts/mlp_b1.hlo.txt
//!   L3 coordinator + simulator   -> routing, batching, cycle counts
//!
//! Run: `cargo run --release --example mlp_inference`
//! (PJRT cross-check leg: `make artifacts`, then add `--features pjrt`.)
//! Results recorded in EXPERIMENTS.md §End-to-end.

use imagine::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, ModelRegistry, Request};
use imagine::engine::EngineConfig;
use imagine::gemv::scheduler::Layer;
#[cfg(feature = "pjrt")]
use imagine::runtime::Runtime;
use imagine::sim::U55_FMAX_MHZ;
use imagine::util::XorShift;
#[cfg(feature = "pjrt")]
use std::path::Path;

const DIMS: [usize; 4] = [784, 256, 128, 10];
const SCALES: [f64; 2] = [0.0078125, 0.0078125]; // 2^-7, matches L2

/// Synthetic "digit": a 28x28 int8 image with a class-dependent stripe
/// pattern plus noise — enough structure for argmax stability checks.
fn synth_digit(rng: &mut XorShift, class: usize) -> Vec<i64> {
    (0..784)
        .map(|i| {
            let row = i / 28;
            let base = if (row + class) % 10 < 3 { 90 } else { -40 };
            (base + rng.range_i64(-30, 30)).clamp(-128, 127)
        })
        .collect()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== IMAGine end-to-end: int8 MLP {DIMS:?} inference ==\n");

    // deterministic int8 model (same generator family as the tests)
    let mut rng = XorShift::new(20240901);
    let mut layers = Vec::new();
    for i in 0..3 {
        let (o, n) = (DIMS[i + 1], DIMS[i]);
        layers.push(Layer::new(
            rng.vec_i64(o * n, -16, 15),
            rng.vec_i64(o, -64, 63),
            o,
            n,
        ));
    }

    // register with the coordinator (2 workers, dynamic batching)
    let reg = ModelRegistry::default();
    reg.register_mlp("digits", layers.clone(), SCALES.to_vec())?;
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            batch: BatchPolicy { max_batch: 8, ..Default::default() },
            engine: EngineConfig::small(),
            precision: 8,
            radix: 2,
            clock_mhz: U55_FMAX_MHZ,
            ..Default::default()
        },
        reg,
    );

    // workload: 64 synthetic digits
    let samples = 64;
    let inputs: Vec<(usize, Vec<i64>)> = (0..samples)
        .map(|i| (i % 10, synth_digit(&mut rng, i % 10)))
        .collect();

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|(_, x)| coord.submit(Request { model: "digits".into(), x: x.clone() }).unwrap())
        .collect();
    let mut results = Vec::new();
    let mut total_cycles = 0u64;
    for rx in rxs {
        let r = rx.recv()??;
        total_cycles += r.cycles;
        results.push(r);
    }
    let wall = t0.elapsed();

    // PJRT cross-check on the first few samples via the mlp_b1 artifact
    #[cfg(feature = "pjrt")]
    {
        let mut rt = Runtime::load(Path::new("artifacts"))?;
        let mut flat: Vec<Vec<i32>> = Vec::new();
        for l in &layers {
            flat.push(l.w.iter().map(|&v| v as i32).collect());
            flat.push(l.bias.iter().map(|&v| v as i32).collect());
        }
        let mut checked = 0;
        for (i, (_, x)) in inputs.iter().take(8).enumerate() {
            let xi: Vec<i32> = x.iter().map(|&v| v as i32).collect();
            let ins: Vec<&[i32]> = std::iter::once(xi.as_slice())
                .chain(flat.iter().map(|v| v.as_slice()))
                .collect();
            let y = rt.execute("mlp_b1", &ins)?;
            let sim: Vec<i32> = results[i].y.iter().map(|&v| v as i32).collect();
            assert_eq!(y, sim, "sample {i}: PJRT artifact vs simulator");
            checked += 1;
        }
        println!("PJRT cross-checked   : {checked}/8 OK (bit-exact)");
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT cross-check     : skipped (build with --features pjrt + make artifacts)");

    let m = coord.shutdown();
    let device_us_per_inf = total_cycles as f64 / samples as f64 / U55_FMAX_MHZ;
    println!("samples              : {samples}");
    println!("host wall time       : {:.1} ms total", wall.as_secs_f64() * 1e3);
    println!(
        "modeled device       : {:.1} us/inference -> {:.0} inf/s at {:.0} MHz",
        device_us_per_inf,
        1e6 / device_us_per_inf,
        U55_FMAX_MHZ
    );
    println!(
        "coordinator          : {} completed, {} batches, mean batch {:.2}, p50 {} us, p99 {} us",
        m.completed,
        m.batches,
        m.mean_batch_size(),
        m.latency_percentile_us(50.0),
        m.latency_percentile_us(99.0)
    );
    println!(
        "\nall layers composed: Pallas kernel -> JAX AOT -> PJRT == coordinator -> simulator."
    );
    Ok(())
}
